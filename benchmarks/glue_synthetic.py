"""Paper Tables 2 & 5 (GLUE/SuperGLUE method comparison) — offline stand-in.

Protocol preserved from the paper: several classification tasks, every PEFT
method fine-tuned on each with the backbone frozen (except `ft`), median
accuracy + std over seeds, Macro = mean over tasks. Datasets are the
synthetic token-identity suite (no network in this container; see
DESIGN.md §3). Expected ranking (paper §4.2): aot_fc >= lora/adapters,
aot_fc > bitfit, ft best.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bench_model, emit, pretrain
from repro.core import aot as A
from repro.core import peft as P
from repro.data.tasks import make_task_suite
from repro.train.step import TrainConfig, make_train_step, split_train

METHODS = ["ft", "aot_fc", "aot_kron", "bitfit", "lora", "adapters",
           "ptv1", "ptv2"]


def _train_eval(cfg, model, params, task, method, seed, steps=120):
    mode = "kron" if method == "aot_kron" else "fc"
    name = "aot" if method.startswith("aot") else method
    popt = P.PEFTOptions(method=name, num_classes=task.num_classes,
                         prompt_len=8, lora_rank=8, adapter_rank=16,
                         aot=A.AoTOptions(mode=mode, rank=16, dropout=0.0))
    pp = P.init(jax.random.PRNGKey(seed), cfg, popt)
    tcfg = TrainConfig(peft=popt, lr=8e-3 if name != "ft" else 1e-3,
                       loss_chunk=0)
    init_state, train_step = make_train_step(model, tcfg, classify=True)
    trainable, frozen = split_train(params, pp, name)
    state = init_state(trainable)
    step = jax.jit(train_step)
    for i in range(steps):
        b = task.batch(16, step=seed * 10_000 + i)
        state, _ = step(state, frozen,
                        {k: jnp.asarray(v) for k, v in b.items()},
                        jax.random.PRNGKey(i))
    merged = state["trainable"].get("backbone", params)
    peft = P.make(state["trainable"]["peft"], popt)
    accs = []
    for i in range(4):
        b = task.batch(32, step=90_000 + i)
        lg, _ = model.classify(merged, {"tokens": jnp.asarray(b["tokens"])}, peft)
        accs.append(float((jnp.argmax(lg, -1) == jnp.asarray(b["labels"])).mean()))
    return float(np.mean(accs))


def run(seeds=(0, 1), n_tasks=3, steps=120):
    cfg, model, params = bench_model(d_model=128, layers=4, vocab=1024)
    params = pretrain(cfg, model, params, steps=40)
    tasks = make_task_suite(cfg.vocab_size, seq_len=32)[:n_tasks]
    macro = {}
    for method in METHODS:
        per_task = []
        for t in tasks:
            accs = [_train_eval(cfg, model, params, t, method, s, steps)
                    for s in seeds]
            med, std = float(np.median(accs)), float(np.std(accs))
            emit(f"glue_synth/{t.name}/{method}", 0.0,
                 f"acc_median={med:.3f} acc_std={std:.3f}")
            per_task.append(med)
        macro[method] = float(np.mean(per_task))
        emit(f"glue_synth/macro/{method}", 0.0, f"macro={macro[method]:.3f}")
    # paper-consistency assertions (soft, reported not raised)
    ok_bitfit = macro["aot_fc"] > macro["bitfit"]
    emit("glue_synth/claim/aot_beats_bitfit", 0.0, f"holds={ok_bitfit}")
    emit("glue_synth/claim/fc_vs_kron", 0.0,
         f"fc={macro['aot_fc']:.3f} kron={macro['aot_kron']:.3f}")
    return macro


if __name__ == "__main__":
    run()
