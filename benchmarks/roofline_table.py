"""EXPERIMENTS.md §Roofline generator: reads results/dryrun/*.json, emits the
per-cell three-term roofline table (and the CSV rows for run.py)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit
from repro import configs
from repro.roofline.analysis import HW_V5E, format_row, roofline_report


def load_cells(out_dir="results/dryrun", tag="pod1"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, f"*__{tag}.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run(out_dir="results/dryrun", tag="pod1", label=None):
    label = label or (f"{tag}-opt" if "opt" in out_dir else f"{tag}-base")
    rows = []
    for cell in load_cells(out_dir, tag):
        name = f"{cell['arch']}/{cell['shape']}"
        if "skipped" in cell:
            emit(f"roofline/{label}/{name}", 0.0, f"SKIP: {cell['skipped']}")
            continue
        cfg = configs.get(cell["arch"])
        shape = cfg.shape(cell["shape"])
        rep = roofline_report(
            flops_per_device=cell["flops_per_device"],
            bytes_per_device=cell["bytes_per_device"],
            coll=cell["collectives"], n_chips=cell["n_chips"],
            cfg=cfg, shape=shape, n_params_total=cell["n_params_total"])
        emit(f"roofline/{label}/{name}", rep["compute_s"] * 1e6,
             f"dom={rep['dominant']} comp_ms={rep['compute_s']*1e3:.3f} "
             f"mem_ms={rep['memory_s']*1e3:.3f} coll_ms={rep['collective_s']*1e3:.3f} "
             f"useful={rep['useful_flops_ratio']:.3f} "
             f"roofline_frac={rep['roofline_fraction']:.3f} "
             f"hbm_gb={cell['memory']['argument_bytes']/1e9 + cell['memory']['temp_bytes']/1e9:.2f}")
        rows.append((cell["arch"], cell["shape"], rep, cell))
    return rows


if __name__ == "__main__":
    run()
