"""Paper App Figs 4-7: accuracy vs number of trained parameters.

Sweeps the AoT FC rank and the P-Tuning v2 prefix length on one task and
reports (params, accuracy) pairs. The paper's point: AoT's rank only affects
*training* parameters — after fusion it vanishes from serving, unlike
p/rank-coupled methods.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_model, emit, pretrain
from benchmarks.glue_synthetic import _train_eval
from repro.core import aot as A
from repro.core import peft as P
from repro.data.tasks import ClassificationTask


def _n_params(cfg, method, rank_or_p):
    if method == "aot_fc":
        opt = P.PEFTOptions(method="aot", num_classes=2,
                            aot=A.AoTOptions(mode="fc", rank=rank_or_p))
    else:
        opt = P.PEFTOptions(method="ptv2", num_classes=2, prompt_len=rank_or_p)
    pp = P.init(jax.random.PRNGKey(0), cfg, opt)
    return sum(x.size for x in jax.tree.leaves(pp))


def run(steps=120):
    cfg, model, params = bench_model(d_model=128, layers=4, vocab=1024)
    params = pretrain(cfg, model, params, steps=40)
    task = ClassificationTask("pe", vocab_size=cfg.vocab_size, seq_len=32,
                              num_classes=2, seed=5)
    for rank in [4, 16, 64]:
        import benchmarks.glue_synthetic as g
        popt_acc = _sweep_acc(cfg, model, params, task, "aot", rank=rank,
                              steps=steps)
        emit(f"param_eff/aot_fc/rank{rank}", 0.0,
             f"params={_n_params(cfg, 'aot_fc', rank)} acc={popt_acc:.3f}")
    for p_len in [4, 16, 64]:
        acc = _sweep_acc(cfg, model, params, task, "ptv2", prompt_len=p_len,
                         steps=steps)
        emit(f"param_eff/ptv2/p{p_len}", 0.0,
             f"params={_n_params(cfg, 'ptv2', p_len)} acc={acc:.3f}")


def _sweep_acc(cfg, model, params, task, method, rank=16, prompt_len=8,
               steps=120):
    import jax.numpy as jnp
    from repro.train.step import TrainConfig, make_train_step, split_train
    popt = P.PEFTOptions(method=method, num_classes=task.num_classes,
                         prompt_len=prompt_len,
                         aot=A.AoTOptions(mode="fc", rank=rank, dropout=0.0))
    pp = P.init(jax.random.PRNGKey(0), cfg, popt)
    tcfg = TrainConfig(peft=popt, lr=8e-3, loss_chunk=0)
    init_state, train_step = make_train_step(model, tcfg, classify=True)
    trainable, frozen = split_train(params, pp, method)
    state = init_state(trainable)
    step = jax.jit(train_step)
    for i in range(steps):
        b = task.batch(16, step=i)
        state, _ = step(state, frozen,
                        {k: jnp.asarray(v) for k, v in b.items()},
                        jax.random.PRNGKey(i))
    peft = P.make(state["trainable"]["peft"], popt)
    accs = []
    for i in range(4):
        b = task.batch(32, step=90_000 + i)
        lg, _ = model.classify(params, {"tokens": jnp.asarray(b["tokens"])}, peft)
        accs.append(float((jnp.argmax(lg, -1) == jnp.asarray(b["labels"])).mean()))
    return float(np.mean(accs))


if __name__ == "__main__":
    run()
